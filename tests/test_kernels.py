"""Pallas block-sparse kernel vs the pure-jnp oracle.

Per the framework rules: shape/dtype sweeps asserting allclose against
``ref.py`` (kernel executed in interpret mode on CPU; TPU is the target).
Randomized sweeps are seeded-``numpy`` parametrizations so the suite runs
on a bare ``jax+pytest`` env (no ``hypothesis`` dependency).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compress import BlockSparseFactor, pack_dense, random_block_factor
from repro.kernels import ref as R
from repro.kernels.bsr_matmul import bsr_matmul
from repro.kernels.ops import blockfaust_apply, blockfaust_apply_t, bsr_apply
from repro.core.compress import BlockFaust

jax.config.update("jax_platform_name", "cpu")


def _rand_factor(key, ib, ob, bk, bn, k, dtype=jnp.float32):
    return random_block_factor(key, ib * bk, ob * bn, bk, bn, k, dtype=dtype)


SHAPES = [
    # (batch, in_blocks, out_blocks, bk, bn, k)
    (8, 4, 4, 8, 8, 2),
    (16, 8, 2, 8, 16, 3),
    (8, 2, 8, 16, 8, 1),
    (32, 4, 4, 8, 8, 4),  # k == ib: fully dense support
    (8, 16, 4, 8, 128, 4),  # lane-dim-sized bn
]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_matches_ref_sweep(shape, dtype):
    b, ib, ob, bk, bn, k = shape
    key = jax.random.PRNGKey(hash(shape) % (2**31))
    f = _rand_factor(key, ib, ob, bk, bn, k, dtype=dtype)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, ib * bk), dtype=dtype)
    got = bsr_matmul(x, f.values, f.in_idx, bt=8, interpret=True)
    want = R.bsr_matmul_ref(x, f.values, f.in_idx)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


def test_ref_matches_dense():
    """The packed representation applied by ref == dense matmul."""
    key = jax.random.PRNGKey(0)
    f = _rand_factor(key, 6, 5, 8, 8, 3)
    x = jax.random.normal(jax.random.PRNGKey(2), (7, 48))
    got = R.bsr_matmul_ref(x, f.values, f.in_idx)
    want = x @ f.todense()
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_pack_dense_roundtrip_apply():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(32, 48)).astype(np.float32))
    # keep all blocks → exact
    f = pack_dense(w, 8, 8, k=4)
    x = jnp.asarray(rng.normal(size=(5, 32)).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(bsr_apply(x, f)), np.asarray(x @ w), rtol=1e-5, atol=1e-5
    )


def test_kernel_grads_match_ref_grads():
    """custom_vjp (Pallas path) gradients == autodiff of the reference."""
    key = jax.random.PRNGKey(3)
    f = _rand_factor(key, 4, 4, 8, 8, 2)
    x = jax.random.normal(jax.random.PRNGKey(4), (8, 32))
    dy_seed = jax.random.normal(jax.random.PRNGKey(5), (8, 32))

    def loss_kernel(x, values):
        fac = BlockSparseFactor(values, f.in_idx, f.in_features, f.out_features)
        y = bsr_apply(x, fac, use_kernel=True, bt=8, interpret=True)
        return jnp.sum(y * dy_seed)

    def loss_ref(x, values):
        fac = BlockSparseFactor(values, f.in_idx, f.in_features, f.out_features)
        y = bsr_apply(x, fac, use_kernel=False)
        return jnp.sum(y * dy_seed)

    gx_k, gv_k = jax.grad(loss_kernel, argnums=(0, 1))(x, f.values)
    gx_r, gv_r = jax.grad(loss_ref, argnums=(0, 1))(x, f.values)
    np.testing.assert_allclose(np.asarray(gx_k), np.asarray(gx_r), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gv_k), np.asarray(gv_r), rtol=1e-4, atol=1e-5)


def test_chain_apply_and_adjoint_match_dense():
    key = jax.random.PRNGKey(6)
    k1, k2, k3 = jax.random.split(key, 3)
    factors = (
        _rand_factor(k1, 4, 6, 8, 8, 2),
        _rand_factor(k2, 6, 6, 8, 8, 3),
        _rand_factor(k3, 6, 8, 8, 8, 2),
    )
    bf = BlockFaust(factors, jnp.asarray(1.3, jnp.float32))
    w = np.asarray(bf.todense())
    x = jax.random.normal(jax.random.PRNGKey(7), (9, 32))
    y = blockfaust_apply(x, bf)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x) @ w, rtol=1e-4, atol=1e-5)
    z = jax.random.normal(jax.random.PRNGKey(8), (9, 64))
    yt = blockfaust_apply_t(z, bf)
    np.testing.assert_allclose(np.asarray(yt), np.asarray(z) @ w.T, rtol=1e-4, atol=1e-5)


def test_chain_apply_kernel_path():
    key = jax.random.PRNGKey(9)
    k1, k2 = jax.random.split(key)
    factors = (
        _rand_factor(k1, 4, 6, 8, 8, 2),
        _rand_factor(k2, 6, 4, 8, 8, 2),
    )
    bf = BlockFaust(factors, jnp.asarray(0.7, jnp.float32))
    x = jax.random.normal(jax.random.PRNGKey(10), (5, 32))  # batch not / bt
    got = blockfaust_apply(x, bf, use_kernel=True, bt=8, interpret=True)
    want = blockfaust_apply(x, bf, use_kernel=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_nonmultiple_feature_padding():
    """in/out features that aren't block multiples (vocab-style padding)."""
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.normal(size=(20, 37)).astype(np.float32))
    f = pack_dense(w, 8, 8, k=3)
    assert f.in_features == 20 and f.out_features == 37
    x = jnp.asarray(rng.normal(size=(3, 20)).astype(np.float32))
    got = bsr_apply(x, f)
    assert got.shape == (3, 37)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(x @ f.todense()), rtol=1e-5, atol=1e-5
    )


@pytest.mark.parametrize("seed", range(12))
def test_random_sweep_kernel_equals_ref(seed):
    """Seeded random-shape sweep (ex-hypothesis property test)."""
    rng = np.random.default_rng(seed)
    b = int(rng.integers(1, 10))
    ib = int(rng.integers(1, 6))
    ob = int(rng.integers(1, 6))
    k = min(int(rng.integers(1, 6)), ib)
    f = _rand_factor(jax.random.PRNGKey(seed), ib, ob, 8, 8, k)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (b, ib * 8))
    got = bsr_apply(x, f, use_kernel=True, bt=8, interpret=True)
    want = bsr_apply(x, f, use_kernel=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_linearity_property():
    """FAµST apply is linear: f(ax + by) == a f(x) + b f(y)."""
    f = _rand_factor(jax.random.PRNGKey(11), 4, 4, 8, 8, 2)
    bf = BlockFaust((f,), jnp.asarray(1.0, jnp.float32))
    x = jax.random.normal(jax.random.PRNGKey(12), (4, 32))
    y = jax.random.normal(jax.random.PRNGKey(13), (4, 32))
    lhs = blockfaust_apply(2.0 * x - 3.0 * y, bf)
    rhs = 2.0 * blockfaust_apply(x, bf) - 3.0 * blockfaust_apply(y, bf)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), rtol=1e-4, atol=1e-4)
