"""Attention reference implementations vs a naive dense oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.layers.attention import (
    AttnSpec,
    KVCache,
    attn_decode,
    attn_init,
    attn_prefill,
    attn_train,
    banded_attention_ref,
    decode_attention,
    flash_attention_ref,
    kv_cache_init,
    kv_cache_positions,
    kv_cache_prefill,
)

jax.config.update("jax_platform_name", "cpu")


def naive_attention(q, k, v, causal=True, window=None):
    b, sq, h, d = q.shape
    _, skv, kh, _ = k.shape
    g = h // kh
    qg = q.reshape(b, sq, kh, g, d)
    s = jnp.einsum("bqhgd,bchd->bhgqc", qg, k) * d**-0.5
    qp = jnp.arange(sq)[:, None]
    kp = jnp.arange(skv)[None, :]
    ok = jnp.ones((sq, skv), bool)
    if causal:
        ok &= kp <= qp
    if window is not None:
        ok &= kp > qp - window
    s = jnp.where(ok, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqc,bchd->bhgqd", p, v)
    return o.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, d)


@pytest.mark.parametrize("h,kh", [(4, 4), (8, 2), (4, 1)])
@pytest.mark.parametrize("chunk", [4, 8, 32])
def test_flash_matches_naive(h, kh, chunk):
    key = jax.random.PRNGKey(0)
    b, s, d = 2, 32, 16
    q = jax.random.normal(key, (b, s, h, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, kh, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, kh, d))
    pos = jnp.arange(s)
    got = flash_attention_ref(q, k, v, q_positions=pos, kv_positions=pos, chunk=chunk)
    want = naive_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("window", [4, 8, 20])
@pytest.mark.parametrize("chunk", [8, 16])
def test_banded_matches_naive_windowed(window, chunk):
    key = jax.random.PRNGKey(3)
    b, s, h, kh, d = 2, 32, 4, 2, 8
    q = jax.random.normal(key, (b, s, h, d))
    k = jax.random.normal(jax.random.PRNGKey(4), (b, s, kh, d))
    v = jax.random.normal(jax.random.PRNGKey(5), (b, s, kh, d))
    got = banded_attention_ref(q, k, v, window=window, chunk=chunk)
    want = naive_attention(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-5)


def test_flash_windowed_matches_naive():
    b, s, h, kh, d = 1, 64, 2, 2, 8
    q = jax.random.normal(jax.random.PRNGKey(6), (b, s, h, d))
    k = jax.random.normal(jax.random.PRNGKey(7), (b, s, kh, d))
    v = jax.random.normal(jax.random.PRNGKey(8), (b, s, kh, d))
    pos = jnp.arange(s)
    got = flash_attention_ref(
        q, k, v, q_positions=pos, kv_positions=pos, window=16, chunk=16
    )
    want = naive_attention(q, k, v, window=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("window", [None, 8])
def test_prefill_then_decode_matches_train(window):
    """Prefill + N decode steps == full-sequence attention on the suffix."""
    spec = AttnSpec(n_heads=4, n_kv_heads=2, head_dim=8, window=window)
    d_model = 16
    p_ann = attn_init(jax.random.PRNGKey(9), d_model, 4, 2, 8)
    from repro.layers.param import split_annotations

    params, _ = split_annotations(p_ann)
    b, s_total, s_prefill = 2, 24, 16
    x = jax.random.normal(jax.random.PRNGKey(10), (b, s_total, d_model))

    # oracle: full self-attention over the whole sequence
    want = attn_train(params, x, spec, chunk=8)

    cap = window if window is not None else s_total
    cache = kv_cache_init(b, cap, 2, 8, dtype=jnp.float32)
    y_pre, cache = attn_prefill(params, x[:, :s_prefill], spec, cache, chunk=8)
    np.testing.assert_allclose(
        np.asarray(y_pre), np.asarray(want[:, :s_prefill]), rtol=2e-4, atol=2e-5
    )
    ys = []
    for t in range(s_prefill, s_total):
        y_t, cache = attn_decode(params, x[:, t : t + 1], spec, cache)
        ys.append(y_t)
    got_dec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(
        np.asarray(got_dec), np.asarray(want[:, s_prefill:]), rtol=2e-3, atol=2e-4
    )


def test_ring_cache_positions():
    cache = kv_cache_init(1, 4, 1, 4, dtype=jnp.float32)
    cache = cache._replace(pos=jnp.asarray(6, jnp.int32))
    pos = np.asarray(kv_cache_positions(cache))
    # slots hold tokens 4,5 (new) and 2,3 (old)
    np.testing.assert_array_equal(pos, [4, 5, 2, 3])


def test_gqa_consistency_with_repeated_kv():
    """GQA == MHA with kv heads repeated."""
    b, s, kh, g, d = 1, 16, 2, 3, 8
    h = kh * g
    q = jax.random.normal(jax.random.PRNGKey(11), (b, s, h, d))
    k = jax.random.normal(jax.random.PRNGKey(12), (b, s, kh, d))
    v = jax.random.normal(jax.random.PRNGKey(13), (b, s, kh, d))
    pos = jnp.arange(s)
    got = flash_attention_ref(q, k, v, q_positions=pos, kv_positions=pos, chunk=8)
    k_rep = jnp.repeat(k, g, axis=2)
    v_rep = jnp.repeat(v, g, axis=2)
    # repeat-kv ordering: head i uses kv head i // g ⇒ q reshaped (kh, g)
    want = flash_attention_ref(q, k_rep, v_rep, q_positions=pos, kv_positions=pos, chunk=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-5)
